//! `resipi` — command-line driver for the ReSiPI reproduction.
//!
//! Subcommands map one-to-one onto the paper's artifacts (DESIGN.md §6):
//!
//! ```text
//! resipi run     --arch resipi --app dedup [--cycles N] [--seed S] [--config F]
//! resipi fig10   [--cycles N]          # design-space exploration → L_m
//! resipi fig11   [--cycles N]          # latency/power/energy grid
//! resipi fig12   [--epochs N] [--epoch-cycles N]
//! resipi fig13   [--cycles N]          # residency heat maps
//! resipi table2                        # controller overhead
//! resipi ablate  <thresholds|gwsel|epoch> [--cycles N]
//! resipi sweep                         # batched HLO power-model sweep
//! resipi all     [--cycles N]          # every artifact, written to results/
//! ```
//!
//! Outputs land in `results/` (override with `RESIPI_RESULTS`). The
//! hand-rolled flag parser exists because the offline build lacks `clap`
//! (DESIGN.md §3).

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;

use resipi::config::{Architecture, Config};
use resipi::experiments::{ablations, fig10, fig11, fig12, fig13, output_dir, scaling, table2};
use resipi::power::controller_area::ControllerParams;
use resipi::runtime::{best_power_model, BatchPowerModel, ARTIFACT_GATEWAYS};
use resipi::sim::{Geometry, Network};
use resipi::traffic::parsec::{app_by_name, ParsecTraffic};
use resipi::traffic::{TraceReader, UniformTraffic};
use resipi::util::io::Json;
use resipi::Result;

/// Parsed `--flag value` arguments.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> std::result::Result<Self, String> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else {
                    let v = argv
                        .get(i + 1)
                        .ok_or_else(|| format!("flag --{name} needs a value"))?;
                    flags.insert(name.to_string(), v.clone());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Self { positional, flags })
    }

    fn get_u64(&self, key: &str, default: u64) -> std::result::Result<u64, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|_| format!("--{key} must be an integer, got {v:?}")),
        }
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }
}

const USAGE: &str = "resipi — ReSiPI 2.5D photonic interposer reproduction

USAGE:
  resipi run    --arch <resipi|resipi-allon|prowaves|awgr|static-gN>
                --app <parsec app|uniform:<rate>|trace:<file>>
                [--cycles N] [--seed S] [--config FILE] [--json]
  resipi fig10  [--cycles N] [--seed S]
  resipi fig11  [--cycles N] [--seed S]
  resipi fig12  [--epochs N] [--epoch-cycles N] [--seed S]
  resipi fig13  [--cycles N] [--seed S]
  resipi table2
  resipi ablate <thresholds|gwsel|epoch> [--cycles N] [--seed S]
  resipi scale  [--cycles N]             # scalability extension (2-8 chiplets)
  resipi sweep
  resipi all    [--cycles N]

Outputs are written under results/ (override with RESIPI_RESULTS).
";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
        print!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd.as_str() {
        "run" => cmd_run(&args),
        "fig10" => cmd_fig10(&args),
        "fig11" => cmd_fig11(&args),
        "fig12" => cmd_fig12(&args),
        "fig13" => cmd_fig13(&args),
        "table2" => cmd_table2(),
        "ablate" => cmd_ablate(&args),
        "scale" => cmd_scale(&args),
        "sweep" => cmd_sweep(),
        "all" => cmd_all(&args),
        other => {
            eprintln!("error: unknown subcommand {other:?}\n\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn out_path(name: &str) -> PathBuf {
    output_dir().join(name)
}

fn cmd_run(args: &Args) -> Result<()> {
    let arch = Architecture::from_name(&args.get_str("arch", "resipi"))?;
    let mut cfg = if let Some(path) = args.flags.get("config") {
        Config::from_file(std::path::Path::new(path))?
    } else {
        Config::table1(arch)
    };
    if args.flags.get("config").is_none() {
        cfg.arch = arch;
    }
    cfg.sim.cycles = args
        .get_u64("cycles", cfg.sim.cycles)
        .map_err(resipi::Error::config)?;
    cfg.sim.seed = args
        .get_u64("seed", cfg.sim.seed)
        .map_err(resipi::Error::config)?;
    cfg.controller.epoch_cycles = args
        .get_u64("epoch-cycles", cfg.controller.epoch_cycles)
        .map_err(resipi::Error::config)?;
    cfg.validate()?;

    let geo = Geometry::from_config(&cfg);
    let app_spec = args.get_str("app", "dedup");
    let traffic: Box<dyn resipi::traffic::Traffic> = if let Some(rate) =
        app_spec.strip_prefix("uniform:")
    {
        let rate: f64 = rate
            .parse()
            .map_err(|_| resipi::Error::config(format!("bad uniform rate {rate:?}")))?;
        Box::new(UniformTraffic::new(geo, rate, cfg.sim.seed))
    } else if let Some(path) = app_spec.strip_prefix("trace:") {
        Box::new(TraceReader::from_file(std::path::Path::new(path))?)
    } else {
        let app = app_by_name(&app_spec)
            .ok_or_else(|| resipi::Error::config(format!("unknown app {app_spec:?}")))?;
        Box::new(ParsecTraffic::new(geo, app, cfg.sim.seed))
    };

    let mut net = Network::with_power_model(cfg, traffic, best_power_model())?;
    net.run()?;
    if args.flags.contains_key("debug") {
        eprintln!("{}", net.congestion_report());
    }
    let s = net.summary();
    if args.flags.contains_key("json") {
        let mut j = Json::obj();
        j.set("arch", s.arch.as_str());
        j.set("traffic", s.traffic.as_str());
        j.set("cycles", s.cycles);
        j.set("created", s.created);
        j.set("delivered", s.delivered);
        j.set("avg_latency_cycles", s.avg_latency_cycles);
        j.set("p99_latency_cycles", s.p99_latency_cycles);
        j.set("avg_power_mw", s.avg_power_mw);
        j.set("total_energy_uj", s.total_energy_uj);
        j.set("energy_metric_pj", s.energy_metric_pj);
        j.set("avg_active_gateways", s.avg_active_gateways);
        j.set("power_backend", s.power_backend);
        println!("{}", j.to_string());
    } else {
        println!("arch:               {}", s.arch);
        println!("traffic:            {}", s.traffic);
        println!("cycles:             {}", s.cycles);
        println!("packets:            {} created / {} delivered", s.created, s.delivered);
        println!("avg latency:        {:.2} cycles (p99 {:.1})", s.avg_latency_cycles, s.p99_latency_cycles);
        println!(
            "avg power:          {:.1} mW  (laser {:.1}, tuning {:.1}, tia {:.1}, driver {:.1}, ctrl {:.3})",
            s.avg_power_mw,
            s.power.laser_mw,
            s.power.tuning_mw,
            s.power.tia_mw,
            s.power.driver_mw,
            s.power.controller_mw
        );
        println!("energy metric:      {:.1} pJ (power × latency)", s.energy_metric_pj);
        println!("total energy:       {:.1} uJ", s.total_energy_uj);
        println!("avg gateways:       {:.2}", s.avg_active_gateways);
        println!("avg wavelengths:    {:.2}", s.avg_total_lambdas);
        println!("power backend:      {}", s.power_backend);
    }
    Ok(())
}

fn cmd_fig10(args: &Args) -> Result<()> {
    let cycles = args.get_u64("cycles", 1_000_000).map_err(resipi::Error::config)?;
    let seed = args.get_u64("seed", 0xF16).map_err(resipi::Error::config)?;
    let accept: f64 = args
        .get_str("accept", "0.10")
        .parse()
        .map_err(|_| resipi::Error::config("--accept must be a number"))?;
    let fig = fig10::run_with_accept(cycles, seed, accept)?;
    fig10::to_csv(&fig).write(&out_path("fig10.csv"))?;
    print!("{}", fig10::report(&fig));
    println!("wrote {}", out_path("fig10.csv").display());
    Ok(())
}

fn cmd_fig11(args: &Args) -> Result<()> {
    let cycles = args.get_u64("cycles", 1_000_000).map_err(resipi::Error::config)?;
    let seed = args.get_u64("seed", 0xF11).map_err(resipi::Error::config)?;
    let fig = fig11::run(cycles, seed)?;
    fig11::to_csv(&fig).write(&out_path("fig11.csv"))?;
    fig11::to_json(&fig).write(&out_path("fig11_headline.json"))?;
    print!("{}", fig11::report(&fig));
    println!("wrote {}", out_path("fig11.csv").display());
    Ok(())
}

fn cmd_fig12(args: &Args) -> Result<()> {
    let epochs = args.get_u64("epochs", 100).map_err(resipi::Error::config)?;
    let epoch_cycles = args
        .get_u64("epoch-cycles", 100_000)
        .map_err(resipi::Error::config)?;
    let seed = args.get_u64("seed", 0xF12).map_err(resipi::Error::config)?;
    let fig = fig12::run(epochs, epoch_cycles, seed)?;
    fig12::to_csv(&fig).write(&out_path("fig12.csv"))?;
    print!("{}", fig12::report(&fig));
    println!("wrote {}", out_path("fig12.csv").display());
    Ok(())
}

fn cmd_fig13(args: &Args) -> Result<()> {
    let cycles = args.get_u64("cycles", 1_000_000).map_err(resipi::Error::config)?;
    let seed = args.get_u64("seed", 0xF13).map_err(resipi::Error::config)?;
    let fig = fig13::run(cycles, seed)?;
    fig13::to_csv(&fig).write(&out_path("fig13.csv"))?;
    print!("{}", fig13::report(&fig));
    println!("wrote {}", out_path("fig13.csv").display());
    Ok(())
}

fn cmd_table2() -> Result<()> {
    let t = table2::run(&ControllerParams::default());
    table2::to_csv(&t).write(&out_path("table2.csv"))?;
    print!("{}", table2::report(&t));
    println!("wrote {}", out_path("table2.csv").display());
    Ok(())
}

fn cmd_ablate(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("thresholds");
    let cycles = args.get_u64("cycles", 600_000).map_err(resipi::Error::config)?;
    let seed = args.get_u64("seed", 0xAB).map_err(resipi::Error::config)?;
    let rows = match which {
        "thresholds" => ablations::thresholds(cycles, seed)?,
        "gwsel" => ablations::gateway_selection(cycles, seed)?,
        "epoch" => ablations::epoch_length(cycles, seed)?,
        other => {
            return Err(resipi::Error::config(format!(
                "unknown ablation {other:?} (thresholds|gwsel|epoch)"
            )))
        }
    };
    ablations::to_csv(&rows).write(&out_path(&format!("ablation_{which}.csv")))?;
    print!("{}", ablations::report(which, &rows));
    Ok(())
}

fn cmd_scale(args: &Args) -> Result<()> {
    let cycles = args.get_u64("cycles", 400_000).map_err(resipi::Error::config)?;
    let seed = args.get_u64("seed", 0x5CA).map_err(resipi::Error::config)?;
    let points = scaling::run(&[2, 4, 6, 8], cycles, seed)?;
    scaling::to_csv(&points).write(&out_path("scaling.csv"))?;
    print!("{}", scaling::report(&points));
    println!("wrote {}", out_path("scaling.csv").display());
    Ok(())
}

fn cmd_sweep() -> Result<()> {
    // Batched HLO power-model sweep over every gateway-count pattern:
    // the §3.4 "pre-analysed scenarios" evaluated on the L1 kernel.
    let model = BatchPowerModel::load_default().map_err(|e| {
        resipi::Error::runtime(format!(
            "{e}; run `make artifacts` first to build the HLO power model"
        ))
    })?;
    let cfg = Config::table1(Architecture::Resipi);
    let mut active = Vec::new();
    let mut lambdas = Vec::new();
    let mut labels = Vec::new();
    for g in 1..=4usize {
        for lam in [1usize, 2, 4, 8] {
            let mut mask = vec![false; ARTIFACT_GATEWAYS];
            for c in 0..4 {
                for k in 0..g {
                    mask[c * 4 + k] = true;
                }
            }
            mask[16] = true;
            mask[17] = true;
            active.push(mask);
            lambdas.push(vec![lam; ARTIFACT_GATEWAYS]);
            labels.push(format!("g={g} lambda={lam}"));
        }
    }
    let spec = resipi::power::ArchPowerSpec::resipi(5);
    let rows = model.evaluate(&active, &lambdas, &cfg.power, &spec)?;
    println!("Batched HLO power-model sweep (backend: hlo-pjrt)");
    println!("config           laser(mW)  tuning    tia       driver    total");
    for (label, r) in labels.iter().zip(&rows) {
        println!(
            "{:<16} {:<10.1} {:<9.1} {:<9.1} {:<9.1} {:<9.1}",
            label, r[0], r[1], r[2], r[3], r[4]
        );
    }
    Ok(())
}

fn cmd_all(args: &Args) -> Result<()> {
    cmd_table2()?;
    cmd_fig10(args)?;
    cmd_fig11(args)?;
    cmd_fig13(args)?;
    let f12 = Args {
        positional: vec![],
        flags: HashMap::from([
            ("epochs".to_string(), "40".to_string()),
            (
                "epoch-cycles".to_string(),
                args.get_str("epoch-cycles", "50000"),
            ),
        ]),
    };
    cmd_fig12(&f12)?;
    for which in ["thresholds", "gwsel", "epoch"] {
        let a = Args {
            positional: vec![which.to_string()],
            flags: args.flags.clone(),
        };
        cmd_ablate(&a)?;
    }
    println!("\nAll artifacts regenerated under {}", output_dir().display());
    Ok(())
}
