#!/usr/bin/env python3
"""Bootstrap mirror of `cargo xtask lint`.

This script re-implements the linter's lexer and rule passes (see
rust/xtask/src/) so the committed lint-baseline.json could be generated in
an environment without a Rust toolchain. It is NOT authoritative — the Rust
implementation in rust/xtask is. If the two ever disagree, fix the Rust
side and regenerate the baseline with `RESIPI_BLESS=1 cargo xtask lint`.

Usage: python3 gen_baseline.py [--root rust/src]  (baseline JSON on stdout,
diagnostics on stderr)
"""

import json
import os
import sys

KEYWORDS = {
    "let", "in", "as", "mut", "ref", "move", "return", "if", "else", "match",
    "const", "static", "break", "continue", "where", "for", "while", "loop",
    "impl", "fn", "pub", "use", "mod", "struct", "enum", "trait", "type",
    "dyn", "unsafe", "crate", "super", "self", "Self", "box", "yield",
    "async", "await", "become", "do", "macro", "union", "true", "false",
}

DENY_METHODS = {
    "push", "push_back", "push_front", "insert", "collect", "to_vec",
    "to_owned", "to_string", "clone", "extend", "extend_from_slice",
    "append", "reserve", "reserve_exact", "resize", "split_off", "join",
    "repeat", "concat",
}

PATH_DENY = {
    ("Box", "new"), ("String", "from"), ("Vec", "with_capacity"),
    ("String", "with_capacity"), ("Vec", "from"),
}

PANIC_MACROS = {"panic", "unreachable", "todo", "unimplemented"}


def lex(text):
    """Tokenize Rust source. Returns (tokens, comments).

    tokens: list of (kind, text, line, col); kind in
      {id, num, str, char, life, punct}. `::` is one punct; every other
      punct is a single char. line/col are 1-based byte positions.
    comments: dict line -> concatenated comment text (block comments are
      recorded at their start line).
    """
    toks = []
    comments = {}
    b = text
    n = len(b)
    i = 0
    line = 1
    col = 1

    def note_comment(at_line, s):
        comments[at_line] = comments.get(at_line, "") + " " + s

    def adv(k=1):
        nonlocal i, line, col
        for _ in range(k):
            if i < n and b[i] == "\n":
                line += 1
                col = 1
            else:
                col += 1
            i += 1

    def string_body(quote):
        # past opening quote; consume until unescaped close
        while i < n:
            c = b[i]
            if c == "\\":
                adv(2)
            elif c == quote:
                adv()
                return
            else:
                adv()

    def raw_string():
        # at 'r' (or after b); consume r#*"..."#*
        adv()  # r
        hashes = 0
        while i < n and b[i] == "#":
            hashes += 1
            adv()
        if i < n and b[i] == '"':
            adv()
            closer = '"' + "#" * hashes
            while i < n:
                if b[i] == '"' and b[i:i + 1 + hashes] == closer:
                    adv(1 + hashes)
                    return
                adv()

    while i < n:
        c = b[i]
        if c in " \t\r\n":
            adv()
            continue
        if c == "/" and b[i + 1:i + 2] == "/":
            start_line = line
            j = b.find("\n", i)
            j = n if j == -1 else j
            note_comment(start_line, b[i:j])
            adv(j - i)
            continue
        if c == "/" and b[i + 1:i + 2] == "*":
            start_line = line
            start = i
            depth = 0
            while i < n:
                if b[i:i + 2] == "/*":
                    depth += 1
                    adv(2)
                elif b[i:i + 2] == "*/":
                    depth -= 1
                    adv(2)
                    if depth == 0:
                        break
                else:
                    adv()
            note_comment(start_line, b[start:i])
            continue
        tl, tc = line, col
        if c == "r" and (b[i + 1:i + 2] == '"' or (b[i + 1:i + 2] == "#" and _raw_ahead(b, i + 1))):
            raw_string()
            toks.append(("str", "", tl, tc))
            continue
        if c == "b" and b[i + 1:i + 2] == '"':
            adv(2)
            string_body('"')
            toks.append(("str", "", tl, tc))
            continue
        if c == "b" and b[i + 1:i + 2] == "'":
            adv(2)
            string_body("'")
            toks.append(("char", "", tl, tc))
            continue
        if c == "b" and b[i + 1:i + 2] == "r" and (b[i + 2:i + 3] == '"' or (b[i + 2:i + 3] == "#" and _raw_ahead(b, i + 2))):
            adv()  # b
            raw_string()
            toks.append(("str", "", tl, tc))
            continue
        if c == '"':
            adv()
            string_body('"')
            toks.append(("str", "", tl, tc))
            continue
        if c == "'":
            nxt = b[i + 1:i + 2]
            if (nxt.isalpha() or nxt == "_") and b[i + 2:i + 3] != "'":
                adv()
                start = i
                while i < n and (b[i].isalnum() or b[i] == "_"):
                    adv()
                toks.append(("life", b[start:i], tl, tc))
            else:
                adv()
                string_body("'")
                toks.append(("char", "", tl, tc))
            continue
        if c.isalpha() or c == "_":
            start = i
            while i < n and (b[i].isalnum() or b[i] == "_"):
                adv()
            toks.append(("id", b[start:i], tl, tc))
            continue
        if c.isdigit():
            start = i
            while i < n:
                ch = b[i]
                if ch.isalnum() or ch == "_":
                    adv()
                elif ch == "." and b[i + 1:i + 2].isdigit():
                    adv()
                else:
                    break
            toks.append(("num", b[start:i], tl, tc))
            continue
        if c == ":" and b[i + 1:i + 2] == ":":
            toks.append(("punct", "::", tl, tc))
            adv(2)
            continue
        toks.append(("punct", c, tl, tc))
        adv()
    return toks, comments


def _raw_ahead(b, j):
    # at b[j] == '#': raw string only if #* then '"'
    while j < len(b) and b[j] == "#":
        j += 1
    return j < len(b) and b[j] == '"'


def match_brace(toks, k):
    """k indexes a '{'; return index of its matching '}'."""
    depth = 0
    for j in range(k, len(toks)):
        t = toks[j]
        if t[0] == "punct" and t[1] == "{":
            depth += 1
        elif t[0] == "punct" and t[1] == "}":
            depth -= 1
            if depth == 0:
                return j
    return len(toks) - 1


def skip_angles(toks, k):
    """k indexes a '<'; return index just past the matching '>'."""
    depth = 0
    j = k
    while j < len(toks):
        t = toks[j]
        if t[0] == "punct" and t[1] == "<":
            depth += 1
        elif t[0] == "punct" and t[1] == ">":
            prev = toks[j - 1]
            if not (prev[0] == "punct" and prev[1] in ("-", "=")):
                depth -= 1
                if depth == 0:
                    return j + 1
        j += 1
    return j


def cfg_test_skips(toks):
    """Boolean array: tokens inside #[cfg(test)] items (incl. the attr)."""
    skipped = [False] * len(toks)
    i = 0
    while i < len(toks):
        shape = [(t[0], t[1]) for t in toks[i:i + 7]]
        if shape == [("punct", "#"), ("punct", "["), ("id", "cfg"),
                     ("punct", "("), ("id", "test"), ("punct", ")"),
                     ("punct", "]")]:
            start = i
            j = i + 7
            # skip any further attributes
            while (j < len(toks) and toks[j][0] == "punct" and toks[j][1] == "#"
                   and j + 1 < len(toks) and toks[j + 1][1] == "["):
                depth = 0
                j += 1
                while j < len(toks):
                    if toks[j][1] == "[":
                        depth += 1
                    elif toks[j][1] == "]":
                        depth -= 1
                        if depth == 0:
                            j += 1
                            break
                    j += 1
            # find first '{' or ';' at () [] nesting 0
            nest = 0
            end = None
            while j < len(toks):
                t = toks[j]
                if t[0] == "punct" and t[1] in ("(", "["):
                    nest += 1
                elif t[0] == "punct" and t[1] in (")", "]"):
                    nest -= 1
                elif t[0] == "punct" and t[1] == "{" and nest == 0:
                    end = match_brace(toks, j)
                    break
                elif t[0] == "punct" and t[1] == ";" and nest == 0:
                    end = j
                    break
                j += 1
            if end is None:
                end = len(toks) - 1
            for k in range(start, end + 1):
                skipped[k] = True
            i = end + 1
            continue
        i += 1
    return skipped


def outline(toks, skipped):
    """Find fn bodies: list of (qualname, body_start_idx, body_end_idx)."""
    fns = []
    impl_stack = []  # (type_name, depth_at_open)
    depth = 0
    i = 0
    while i < len(toks):
        if skipped[i]:
            i += 1
            continue
        t = toks[i]
        if t[0] == "punct" and t[1] == "{":
            depth += 1
        elif t[0] == "punct" and t[1] == "}":
            depth -= 1
            while impl_stack and impl_stack[-1][1] >= depth:
                impl_stack.pop()
        elif t[0] == "id" and t[1] == "impl":
            j = i + 1
            if j < len(toks) and toks[j][0] == "punct" and toks[j][1] == "<":
                j = skip_angles(toks, j)
            cur = []
            while j < len(toks):
                tj = toks[j]
                if tj[0] == "punct" and tj[1] in ("{", ";"):
                    break
                if tj[0] == "id" and tj[1] == "for":
                    cur = []
                elif tj[0] == "id" and tj[1] == "where":
                    break
                elif tj[0] == "punct" and tj[1] == "<":
                    j = skip_angles(toks, j)
                    continue
                elif tj[0] == "id":
                    cur.append(tj[1])
                j += 1
            # advance to the '{' (or ';') so the main loop sees it
            while j < len(toks) and not (toks[j][0] == "punct" and toks[j][1] in ("{", ";")):
                j += 1
            if j < len(toks) and toks[j][1] == "{" and cur:
                impl_stack.append((cur[-1], depth))
            i = j
            continue
        elif t[0] == "id" and t[1] == "fn":
            if i + 1 < len(toks) and toks[i + 1][0] == "id":
                name = toks[i + 1][1]
                qual = (impl_stack[-1][0] + "::" + name) if impl_stack else name
                k = i + 2
                nest = 0
                while k < len(toks):
                    tk = toks[k]
                    if tk[0] == "punct" and tk[1] in ("(", "["):
                        nest += 1
                    elif tk[0] == "punct" and tk[1] in (")", "]"):
                        nest -= 1
                    elif tk[0] == "punct" and tk[1] == "{" and nest == 0:
                        break
                    elif tk[0] == "punct" and tk[1] == ";" and nest == 0:
                        break
                    k += 1
                if k < len(toks) and toks[k][1] == "{":
                    fns.append((qual, k, match_brace(toks, k)))
        i += 1
    return fns


RULES = ("no-random-state", "no-wall-clock", "hot-path-no-alloc",
         "no-panic-in-parsers", "checked-narrowing")


def has_allow_marker(text, rule):
    idx = 0
    while True:
        at = text.find("allow(resipi::", idx)
        if at == -1:
            return False
        end = text.find(")", at)
        if end == -1:
            return False
        inner = text[at + len("allow("):end]
        for part in inner.split(","):
            slug = part.strip().replace("resipi::", "").replace("_", "-")
            if slug == rule or slug == "all":
                return True
        idx = end + 1


def suppressed(comments, lines, rule, line):
    # A marker suppresses on its own line, on the line below it, or from
    # anywhere inside a contiguous block of comment-only lines directly
    # above the violation (justifications are encouraged to span lines).
    if has_allow_marker(comments.get(line, ""), rule):
        return True
    l = line - 1
    while l >= 1 and l in comments:
        if has_allow_marker(comments[l], rule):
            return True
        src = lines[l - 1].strip() if l - 1 < len(lines) else ""
        if not (src.startswith("//") or src.startswith("/*") or src.startswith("*")):
            break
        l -= 1
    return False


def lint_file(path, rel, cfgd):
    text = open(path, encoding="utf-8").read()
    lines = text.split("\n")
    toks, comments = lex(text)
    skipped = cfg_test_skips(toks)
    fns = outline(toks, skipped)
    viols = []

    def emit(rule, tok):
        line, col = tok[2], tok[3]
        snippet = lines[line - 1].strip() if line - 1 < len(lines) else ""
        status = "suppressed" if suppressed(comments, lines, rule, line) else "open"
        viols.append({"rule": rule, "file": rel, "line": line, "col": col,
                      "snippet": snippet, "status": status})

    for idx, t in enumerate(toks):
        if skipped[idx]:
            continue
        kind, txt = t[0], t[1]
        nxt = toks[idx + 1] if idx + 1 < len(toks) else ("punct", "", 0, 0)
        nx2 = toks[idx + 2] if idx + 2 < len(toks) else ("punct", "", 0, 0)
        if kind == "id" and txt in ("HashMap", "HashSet") and rel not in cfgd["r1_allow"]:
            emit("no-random-state", t)
        if kind == "id" and txt in ("Instant", "SystemTime") and rel not in cfgd["r2_allow"]:
            emit("no-wall-clock", t)
        if rel in cfgd["r5_files"] and kind == "id" and txt == "as" \
                and nxt[0] == "id" and nxt[1] in ("u8", "u16", "u32"):
            emit("checked-narrowing", t)
        if rel in cfgd["r4_files"]:
            if kind == "punct" and txt == "." and nxt[0] == "id" \
                    and nxt[1] in ("unwrap", "expect") and nx2[1] == "(":
                emit("no-panic-in-parsers", nxt)
            if kind == "id" and txt in PANIC_MACROS and nxt[0] == "punct" and nxt[1] == "!":
                emit("no-panic-in-parsers", t)
            if kind == "punct" and txt == "[" and idx > 0:
                prev = toks[idx - 1]
                postfix = (prev[0] == "punct" and prev[1] in (")", "]", "?")) or \
                          (prev[0] == "id" and prev[1] not in KEYWORDS)
                if postfix:
                    emit("no-panic-in-parsers", t)

    for qual, b0, b1 in fns:
        if qual not in cfgd["hotpaths"]:
            continue
        for idx in range(b0, b1 + 1):
            if skipped[idx]:
                continue
            t = toks[idx]
            nxt = toks[idx + 1] if idx + 1 < len(toks) else ("punct", "", 0, 0)
            nx2 = toks[idx + 2] if idx + 2 < len(toks) else ("punct", "", 0, 0)
            nx3 = toks[idx + 3] if idx + 3 < len(toks) else ("punct", "", 0, 0)
            if t[0] == "punct" and t[1] == "." and nxt[0] == "id" \
                    and nxt[1] in DENY_METHODS and nx2[1] == "(":
                emit("hot-path-no-alloc", nxt)
            if t[0] == "id" and t[1] in ("format", "vec") and nxt[0] == "punct" and nxt[1] == "!":
                emit("hot-path-no-alloc", t)
            if t[0] == "id" and nxt[1] == "::" and nx2[0] == "id" \
                    and (t[1], nx2[1]) in PATH_DENY and nx3[1] == "(":
                emit("hot-path-no-alloc", t)

    return viols


def lint_tree(root, cfgd):
    out = []
    files = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for f in filenames:
            if f.endswith(".rs"):
                full = os.path.join(dirpath, f)
                files.append((os.path.relpath(full, root).replace(os.sep, "/"), full))
    files.sort()
    for rel, full in files:
        out.extend(lint_file(full, rel, cfgd))
    out.sort(key=lambda v: (v["file"], v["line"], v["col"], v["rule"]))
    return out


REPO_CFG = {
    "hotpaths": {
        "Network::step", "Network::epoch_boundary",
        "RouteTable::step", "RouteTable::route_packet",
        "UniformTraffic::generate", "TransposeTraffic::generate",
        "HotspotTraffic::generate", "ComposedTraffic::generate",
        "BinTraceReader::generate", "BinTraceReader::next_record",
        "Photonic::arrivals_into",
    },
    "r1_allow": set(),
    "r2_allow": {"util/bench.rs", "experiments/perf.rs"},
    "r4_files": {"config/parser.rs", "util/io.rs", "traffic/tracebin.rs",
                 "traffic/spec.rs", "config/mod.rs"},
    "r5_files": {"routing/mod.rs", "coordinator/gateway_select.rs"},
}


def main():
    root = "rust/src"
    args = sys.argv[1:]
    if "--root" in args:
        root = args[args.index("--root") + 1]
    viols = lint_tree(root, REPO_CFG)
    open_v = [v for v in viols if v["status"] == "open"]
    sup_v = [v for v in viols if v["status"] == "suppressed"]
    print(f"{len(viols)} violations ({len(open_v)} open, {len(sup_v)} suppressed)",
          file=sys.stderr)
    for v in viols:
        print(f"{v['file']}:{v['line']}:{v['col']} {v['rule']} [{v['status']}] {v['snippet']}",
              file=sys.stderr)
    # Baseline = open violations, keyed by (rule, file, snippet) with counts.
    counts = {}
    for v in open_v:
        key = (v["file"], v["rule"], v["snippet"])
        counts[key] = counts.get(key, 0) + 1
    entries = [{"rule": r, "file": f, "snippet": s, "count": c}
               for (f, r, s), c in sorted(counts.items())]
    baseline = {
        "version": 1,
        "note": "Grandfathered lint violations; new violations fail `cargo xtask "
                "lint`. Shrink by fixing sites and re-blessing with RESIPI_BLESS=1.",
        "entries": entries,
    }
    print(json.dumps(baseline, indent=2))


if __name__ == "__main__":
    main()
