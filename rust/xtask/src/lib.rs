//! Repo tooling for the ReSiPI simulator. The one subcommand today is
//! `cargo xtask lint`: a dependency-free AST-level linter enforcing the
//! crate's determinism, zero-alloc, panic-freedom, and checked-narrowing
//! contracts (see README "Static analysis & invariants").
//!
//! Library layout:
//! - [`lexer`]: tokenizer with comment capture
//! - [`outline`]: `#[cfg(test)]` masking + impl/fn outline
//! - [`lint`]: the five rules and the tree driver
//! - [`manifest`]: `lint-hotpaths.toml` reader
//! - [`baseline`]: grandfathered-violation matching and blessing
//! - [`report`]: stable JSON report
//! - [`json`]: hand-rolled JSON reader/writer

pub mod baseline;
pub mod json;
pub mod lexer;
pub mod lint;
pub mod manifest;
pub mod outline;
pub mod report;
