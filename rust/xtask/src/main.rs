//! `cargo xtask lint [--bless] [--json PATH]`
//!
//! Exit codes: 0 clean (baselined/suppressed findings allowed), 2 new
//! violations, 1 internal error (bad manifest, unreadable tree, ...).
//! `--bless` (or env `RESIPI_BLESS=1`) rewrites `lint-baseline.json` from
//! the current findings instead of failing; use it to ratchet the baseline
//! *down* after fixing grandfathered sites.

use std::env;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use xtask::baseline::{classify, parse_baseline, Status};
use xtask::lint::{lint_tree, rule_help};
use xtask::{baseline, manifest, report};

fn main() -> ExitCode {
    let args: Vec<String> = env::args().skip(1).collect();
    match run(&args) {
        Ok(code) => ExitCode::from(code),
        Err(msg) => {
            eprintln!("xtask: error: {msg}");
            ExitCode::from(1)
        }
    }
}

fn usage() -> String {
    "usage: cargo xtask lint [--bless] [--json PATH]\n\
     \n\
     Lints rust/src against the five repo invariants (no-random-state,\n\
     no-wall-clock, hot-path-no-alloc, no-panic-in-parsers,\n\
     checked-narrowing). Scoping lives in rust/lint-hotpaths.toml;\n\
     grandfathered sites in lint-baseline.json. New violations exit 2.\n\
     --bless (or RESIPI_BLESS=1) rewrites the baseline instead."
        .to_string()
}

fn run(args: &[String]) -> Result<u8, String> {
    let Some(cmd) = args.first() else {
        return Err(usage());
    };
    if cmd == "--help" || cmd == "-h" || cmd == "help" {
        println!("{}", usage());
        return Ok(0);
    }
    if cmd != "lint" {
        return Err(format!("unknown subcommand {cmd:?}\n{}", usage()));
    }

    let mut bless = env::var("RESIPI_BLESS").map(|v| v == "1").unwrap_or(false);
    let mut json_out: Option<PathBuf> = None;
    let mut it = args.iter().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--bless" => bless = true,
            "--json" => {
                let p = it.next().ok_or("--json requires a path argument")?;
                json_out = Some(PathBuf::from(p));
            }
            other => return Err(format!("unknown flag {other:?}\n{}", usage())),
        }
    }

    // xtask lives at <repo>/rust/xtask, so the repo root is two levels up.
    let repo = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let src_root = repo.join("rust/src");
    let manifest_path = repo.join("rust/lint-hotpaths.toml");
    let baseline_path = repo.join("lint-baseline.json");

    let manifest_text = fs::read_to_string(&manifest_path)
        .map_err(|e| format!("cannot read {}: {e}", manifest_path.display()))?;
    let cfg = manifest::from_manifest(&manifest_text)?;

    let viols = lint_tree(&src_root, &cfg)
        .map_err(|e| format!("cannot lint {}: {e}", src_root.display()))?;

    if bless {
        let text = baseline::serialize(&viols);
        fs::write(&baseline_path, &text)
            .map_err(|e| format!("cannot write {}: {e}", baseline_path.display()))?;
        let n = viols.iter().filter(|v| !v.suppressed).count();
        println!(
            "xtask lint: blessed {} violation(s) into {}",
            n,
            baseline_path.display()
        );
        return Ok(0);
    }

    let baseline_entries = match fs::read_to_string(&baseline_path) {
        Ok(text) => parse_baseline(&text)?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => return Err(format!("cannot read {}: {e}", baseline_path.display())),
    };
    let classified = classify(&viols, &baseline_entries);

    let report_text = report::render("rust/src", &viols, &classified);
    let out_path = json_out.unwrap_or_else(|| repo.join("rust/target/lint-report.json"));
    if let Some(dir) = out_path.parent() {
        fs::create_dir_all(dir)
            .map_err(|e| format!("cannot create {}: {e}", dir.display()))?;
    }
    fs::write(&out_path, &report_text)
        .map_err(|e| format!("cannot write {}: {e}", out_path.display()))?;

    // Human diagnostics: new violations in full, with the rule rationale;
    // grandfathered/suppressed sites only in the summary counts.
    let mut shown_help: Vec<&str> = Vec::new();
    for (v, status) in viols.iter().zip(&classified.statuses) {
        if *status != Status::New {
            continue;
        }
        println!("rust/src/{}:{}:{}: [{}] {}", v.file, v.line, v.col, v.rule, v.snippet);
        if !shown_help.contains(&v.rule) {
            shown_help.push(v.rule);
            println!("    = help: {}", rule_help(v.rule));
            println!(
                "    = note: suppress with `// allow(resipi::{}): <justification>`",
                v.rule
            );
        }
    }
    for e in &classified.stale {
        println!(
            "warning: stale baseline entry ({} in {}, count {}) — fixed? re-bless with \
             RESIPI_BLESS=1 to shrink the baseline",
            e.rule, e.file, e.count
        );
    }
    let suppressed = classified
        .statuses
        .iter()
        .filter(|s| **s == Status::Suppressed)
        .count();
    let baselined = classified
        .statuses
        .iter()
        .filter(|s| **s == Status::Baselined)
        .count();
    println!(
        "xtask lint: {} new, {} baselined, {} suppressed, {} stale baseline entr{} \
         (report: {})",
        classified.new_count,
        baselined,
        suppressed,
        classified.stale.len(),
        if classified.stale.len() == 1 { "y" } else { "ies" },
        out_path.display()
    );
    if classified.new_count > 0 {
        println!("xtask lint: FAILED — fix the sites above, suppress with a justification, or");
        println!("  (for pre-existing debt only) re-bless: RESIPI_BLESS=1 cargo xtask lint");
        return Ok(2);
    }
    println!("xtask lint: OK");
    Ok(0)
}
