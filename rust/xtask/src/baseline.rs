//! The grandfathered-violation baseline (`lint-baseline.json` at the repo
//! root). Entries are keyed by (rule, file, trimmed source snippet) with a
//! count, deliberately **not** by line number, so unrelated edits that
//! shift lines do not invalidate the baseline.
//!
//! New violations (not suppressed, not covered by a baseline allowance)
//! fail the lint. Baseline entries that no longer match anything are
//! *stale*: a warning nudging a re-bless (`RESIPI_BLESS=1` or `--bless`),
//! never an error, so fixing old sites is always safe.

use std::collections::BTreeMap;

use crate::json::{self, Value};
use crate::lint::Violation;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BaselineEntry {
    pub rule: String,
    pub file: String,
    pub snippet: String,
    pub count: u64,
}

pub fn parse_baseline(text: &str) -> Result<Vec<BaselineEntry>, String> {
    let root = json::parse(text)?;
    let version = root
        .get("version")
        .and_then(Value::as_u64)
        .ok_or("baseline: missing integer `version`")?;
    if version != 1 {
        return Err(format!("baseline: unsupported version {version}"));
    }
    let entries = root
        .get("entries")
        .and_then(Value::as_arr)
        .ok_or("baseline: missing `entries` array")?;
    let mut out = Vec::new();
    for (i, e) in entries.iter().enumerate() {
        let field = |k: &str| {
            e.get(k)
                .and_then(Value::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("baseline: entry {i} missing string `{k}`"))
        };
        out.push(BaselineEntry {
            rule: field("rule")?,
            file: field("file")?,
            snippet: field("snippet")?,
            count: e
                .get("count")
                .and_then(Value::as_u64)
                .ok_or_else(|| format!("baseline: entry {i} missing integer `count`"))?,
        });
    }
    Ok(out)
}

/// Per-violation status after baseline matching.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Suppressed,
    Baselined,
    New,
}

impl Status {
    pub fn as_str(self) -> &'static str {
        match self {
            Status::Suppressed => "suppressed",
            Status::Baselined => "baselined",
            Status::New => "new",
        }
    }
}

pub struct Classified {
    /// Parallel to the input violation slice.
    pub statuses: Vec<Status>,
    pub new_count: usize,
    /// Baseline entries (or remainders of them) that matched nothing.
    pub stale: Vec<BaselineEntry>,
}

pub fn classify(viols: &[Violation], baseline: &[BaselineEntry]) -> Classified {
    let mut allowance: BTreeMap<(&str, &str, &str), u64> = BTreeMap::new();
    for e in baseline {
        *allowance
            .entry((e.rule.as_str(), e.file.as_str(), e.snippet.as_str()))
            .or_insert(0) += e.count;
    }
    let mut statuses = Vec::with_capacity(viols.len());
    let mut new_count = 0usize;
    for v in viols {
        if v.suppressed {
            statuses.push(Status::Suppressed);
            continue;
        }
        let key = (v.rule, v.file.as_str(), v.snippet.as_str());
        match allowance.get_mut(&key) {
            Some(n) if *n > 0 => {
                *n -= 1;
                statuses.push(Status::Baselined);
            }
            _ => {
                new_count += 1;
                statuses.push(Status::New);
            }
        }
    }
    let stale = allowance
        .into_iter()
        .filter(|&(_, n)| n > 0)
        .map(|((rule, file, snippet), count)| BaselineEntry {
            rule: rule.to_string(),
            file: file.to_string(),
            snippet: snippet.to_string(),
            count,
        })
        .collect();
    Classified {
        statuses,
        new_count,
        stale,
    }
}

/// Serialize the *current* unsuppressed violations as a fresh baseline
/// (what `--bless` writes).
pub fn serialize(viols: &[Violation]) -> String {
    let mut counts: BTreeMap<(&str, &str, &str), u64> = BTreeMap::new();
    for v in viols.iter().filter(|v| !v.suppressed) {
        *counts
            .entry((v.rule, v.file.as_str(), v.snippet.as_str()))
            .or_insert(0) += 1;
    }
    let mut out = String::new();
    out.push_str("{\n  \"version\": 1,\n  \"note\": ");
    json::write_str(
        &mut out,
        "Grandfathered lint violations; new violations fail `cargo xtask lint`. \
         Shrink by fixing sites and re-blessing with RESIPI_BLESS=1.",
    );
    out.push_str(",\n  \"entries\": [");
    let mut first = true;
    for ((rule, file, snippet), count) in &counts {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str("\n    {\"rule\": ");
        json::write_str(&mut out, rule);
        out.push_str(", \"file\": ");
        json::write_str(&mut out, file);
        out.push_str(", \"snippet\": ");
        json::write_str(&mut out, snippet);
        out.push_str(&format!(", \"count\": {count}}}"));
    }
    out.push_str("\n  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(rule: &'static str, file: &str, snippet: &str, suppressed: bool) -> Violation {
        Violation {
            rule,
            file: file.to_string(),
            line: 1,
            col: 1,
            snippet: snippet.to_string(),
            suppressed,
        }
    }

    #[test]
    fn baselined_new_and_stale_are_distinguished() {
        let viols = vec![
            v("no-random-state", "a.rs", "let m = HashMap::new();", false),
            v("no-random-state", "a.rs", "let n = HashMap::new();", false),
            v("no-wall-clock", "b.rs", "Instant::now()", true),
        ];
        let baseline = vec![
            BaselineEntry {
                rule: "no-random-state".to_string(),
                file: "a.rs".to_string(),
                snippet: "let m = HashMap::new();".to_string(),
                count: 1,
            },
            BaselineEntry {
                rule: "checked-narrowing".to_string(),
                file: "gone.rs".to_string(),
                snippet: "x as u8".to_string(),
                count: 1,
            },
        ];
        let c = classify(&viols, &baseline);
        assert_eq!(
            c.statuses,
            vec![Status::Baselined, Status::New, Status::Suppressed]
        );
        assert_eq!(c.new_count, 1);
        assert_eq!(c.stale.len(), 1);
        assert_eq!(c.stale[0].file, "gone.rs");
    }

    #[test]
    fn serialize_then_parse_round_trips() {
        let viols = vec![
            v("no-random-state", "a.rs", "let m = HashMap::new();", false),
            v("no-random-state", "a.rs", "let m = HashMap::new();", false),
            v("no-wall-clock", "b.rs", "Instant::now()", true),
        ];
        let text = serialize(&viols);
        let parsed = parse_baseline(&text).unwrap();
        assert_eq!(parsed.len(), 1, "suppressed sites are not baselined");
        assert_eq!(parsed[0].count, 2);
        let c = classify(&viols, &parsed);
        assert_eq!(c.new_count, 0);
        assert!(c.stale.is_empty());
    }
}
