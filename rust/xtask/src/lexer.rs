//! Minimal Rust lexer for the lint passes.
//!
//! Token-level, not a full grammar: enough structure (identifiers,
//! punctuation with `::` fused, string/char/lifetime literals skipped as
//! opaque units, comments captured per line) for reliable outline parsing
//! and rule matching. Positions are 1-based byte offsets.
//!
//! The Python bootstrap mirror (`tools/gen_baseline.py`) re-implements this
//! algorithm; this Rust implementation is the authoritative one.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Id,
    Num,
    Str,
    Char,
    Life,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// Lex output: the token stream plus per-line comment text (block comments
/// are recorded at their start line), used by the suppression lookup.
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: BTreeMap<u32, String>,
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
    line: u32,
    col: u32,
}

impl Cursor<'_> {
    fn adv(&mut self, k: usize) {
        for _ in 0..k {
            if self.i < self.b.len() && self.b[self.i] == b'\n' {
                self.line += 1;
                self.col = 1;
            } else {
                self.col += 1;
            }
            self.i += 1;
        }
    }

    fn at(&self, off: usize) -> u8 {
        self.b.get(self.i + off).copied().unwrap_or(0)
    }

    /// Past the opening quote: consume until the unescaped closer.
    fn string_body(&mut self, quote: u8) {
        while self.i < self.b.len() {
            let c = self.b[self.i];
            if c == b'\\' {
                self.adv(2);
            } else if c == quote {
                self.adv(1);
                return;
            } else {
                self.adv(1);
            }
        }
    }

    /// At the `r` of `r#*"..."#*`: consume the whole raw string.
    fn raw_string(&mut self) {
        self.adv(1); // r
        let mut hashes = 0usize;
        while self.at(0) == b'#' {
            hashes += 1;
            self.adv(1);
        }
        if self.at(0) == b'"' {
            self.adv(1);
            while self.i < self.b.len() {
                if self.b[self.i] == b'"' && (1..=hashes).all(|k| self.at(k) == b'#') {
                    self.adv(1 + hashes);
                    return;
                }
                self.adv(1);
            }
        }
    }
}

/// At a `#` following `r` / `br`: raw string only if `#*` then `"`.
fn raw_ahead(b: &[u8], mut j: usize) -> bool {
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"'
}

fn is_id_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_id_continue(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

fn note_comment(map: &mut BTreeMap<u32, String>, line: u32, s: &str) {
    let e = map.entry(line).or_default();
    e.push(' ');
    e.push_str(s);
}

pub fn lex(text: &str) -> Lexed {
    let b = text.as_bytes();
    let mut cur = Cursor {
        b,
        i: 0,
        line: 1,
        col: 1,
    };
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: BTreeMap<u32, String> = BTreeMap::new();

    while cur.i < b.len() {
        let c = b[cur.i];
        if c == b' ' || c == b'\t' || c == b'\r' || c == b'\n' {
            cur.adv(1);
            continue;
        }
        if c == b'/' && cur.at(1) == b'/' {
            let start_line = cur.line;
            let start = cur.i;
            while cur.i < b.len() && b[cur.i] != b'\n' {
                cur.adv(1);
            }
            note_comment(&mut comments, start_line, &text[start..cur.i]);
            continue;
        }
        if c == b'/' && cur.at(1) == b'*' {
            let start_line = cur.line;
            let start = cur.i;
            let mut depth = 0i32;
            while cur.i < b.len() {
                if cur.at(0) == b'/' && cur.at(1) == b'*' {
                    depth += 1;
                    cur.adv(2);
                } else if cur.at(0) == b'*' && cur.at(1) == b'/' {
                    depth -= 1;
                    cur.adv(2);
                    if depth == 0 {
                        break;
                    }
                } else {
                    cur.adv(1);
                }
            }
            note_comment(&mut comments, start_line, &text[start..cur.i]);
            continue;
        }
        let (tl, tc) = (cur.line, cur.col);
        if c == b'r' && (cur.at(1) == b'"' || (cur.at(1) == b'#' && raw_ahead(b, cur.i + 1))) {
            cur.raw_string();
            toks.push(Tok {
                kind: Kind::Str,
                text: String::new(),
                line: tl,
                col: tc,
            });
            continue;
        }
        if c == b'b' && cur.at(1) == b'"' {
            cur.adv(2);
            cur.string_body(b'"');
            toks.push(Tok {
                kind: Kind::Str,
                text: String::new(),
                line: tl,
                col: tc,
            });
            continue;
        }
        if c == b'b' && cur.at(1) == b'\'' {
            cur.adv(2);
            cur.string_body(b'\'');
            toks.push(Tok {
                kind: Kind::Char,
                text: String::new(),
                line: tl,
                col: tc,
            });
            continue;
        }
        if c == b'b'
            && cur.at(1) == b'r'
            && (cur.at(2) == b'"' || (cur.at(2) == b'#' && raw_ahead(b, cur.i + 2)))
        {
            cur.adv(1); // b
            cur.raw_string();
            toks.push(Tok {
                kind: Kind::Str,
                text: String::new(),
                line: tl,
                col: tc,
            });
            continue;
        }
        if c == b'"' {
            cur.adv(1);
            cur.string_body(b'"');
            toks.push(Tok {
                kind: Kind::Str,
                text: String::new(),
                line: tl,
                col: tc,
            });
            continue;
        }
        if c == b'\'' {
            // Lifetime unless it closes as a char literal ('a' vs 'a).
            let nxt = cur.at(1);
            if is_id_start(nxt) && cur.at(2) != b'\'' {
                cur.adv(1);
                let start = cur.i;
                while cur.i < b.len() && is_id_continue(b[cur.i]) {
                    cur.adv(1);
                }
                toks.push(Tok {
                    kind: Kind::Life,
                    text: text[start..cur.i].to_string(),
                    line: tl,
                    col: tc,
                });
            } else {
                cur.adv(1);
                cur.string_body(b'\'');
                toks.push(Tok {
                    kind: Kind::Char,
                    text: String::new(),
                    line: tl,
                    col: tc,
                });
            }
            continue;
        }
        if is_id_start(c) {
            let start = cur.i;
            while cur.i < b.len() && is_id_continue(b[cur.i]) {
                cur.adv(1);
            }
            toks.push(Tok {
                kind: Kind::Id,
                text: text[start..cur.i].to_string(),
                line: tl,
                col: tc,
            });
            continue;
        }
        if c.is_ascii_digit() {
            let start = cur.i;
            while cur.i < b.len() {
                let ch = b[cur.i];
                if is_id_continue(ch) {
                    cur.adv(1);
                } else if ch == b'.' && cur.at(1).is_ascii_digit() {
                    cur.adv(1);
                } else {
                    break;
                }
            }
            toks.push(Tok {
                kind: Kind::Num,
                text: text[start..cur.i].to_string(),
                line: tl,
                col: tc,
            });
            continue;
        }
        if c == b':' && cur.at(1) == b':' {
            toks.push(Tok {
                kind: Kind::Punct,
                text: "::".to_string(),
                line: tl,
                col: tc,
            });
            cur.adv(2);
            continue;
        }
        toks.push(Tok {
            kind: Kind::Punct,
            text: (c as char).to_string(),
            line: tl,
            col: tc,
        });
        cur.adv(1);
    }
    Lexed { toks, comments }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src)
            .toks
            .into_iter()
            .map(|t| (t.kind, t.text))
            .collect()
    }

    #[test]
    fn fuses_path_separator() {
        let toks = kinds("a::b");
        assert_eq!(toks.len(), 3);
        assert_eq!(toks[1], (Kind::Punct, "::".to_string()));
    }

    #[test]
    fn skips_strings_chars_and_lifetimes() {
        let toks = kinds(r#"let s = "x[0].unwrap()"; let c = 'a'; fn f<'b>() {}"#);
        // The string and char bodies must not leak tokens.
        assert!(toks
            .iter()
            .all(|(_, t)| t != "unwrap" && t != "x" && t != "a"));
        assert!(toks.iter().any(|(k, t)| *k == Kind::Life && t == "b"));
    }

    #[test]
    fn raw_strings_are_opaque() {
        let toks = kinds(r###"let s = r#"panic!("no")"#; s"###);
        let panics = toks.iter().filter(|(_, t)| t == "panic").count();
        assert_eq!(panics, 0);
    }

    #[test]
    fn comments_are_recorded_per_line() {
        let lexed = lex("let a = 1; // allow(resipi::all): x\nlet b = 2;\n");
        assert!(lexed.comments.get(&1).is_some());
        assert!(lexed.comments.get(&2).is_none());
        assert!(lexed.comments[&1].contains("allow(resipi::all)"));
    }

    #[test]
    fn positions_are_one_based() {
        let lexed = lex("ab cd\n  ef");
        let t = &lexed.toks;
        assert_eq!((t[0].line, t[0].col), (1, 1));
        assert_eq!((t[1].line, t[1].col), (1, 4));
        assert_eq!((t[2].line, t[2].col), (2, 3));
    }

    #[test]
    fn nested_block_comments() {
        let lexed = lex("/* a /* b */ c */ x");
        assert_eq!(lexed.toks.len(), 1);
        assert_eq!(lexed.toks[0].text, "x");
    }
}
