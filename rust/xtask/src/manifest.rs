//! Reader for `rust/lint-hotpaths.toml`, the rule-scoping manifest.
//!
//! Hand-rolled TOML subset (the image ships no `toml` crate): `[section]`
//! headers, `key = [ "quoted", "strings" ]` arrays (multi-line allowed),
//! `#` comments. That is the entire grammar the manifest needs; anything
//! else is a hard error so typos cannot silently widen a rule's scope.

use crate::lint::LintConfig;

pub fn from_manifest(text: &str) -> Result<LintConfig, String> {
    let mut cfg = LintConfig::default();
    let mut section = String::new();
    let mut pending: Option<(String, String)> = None; // (key, accumulated value)

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw);
        let line = line.trim();
        if let Some((key, acc)) = pending.take() {
            let acc = format!("{acc} {line}");
            if balanced(&acc) {
                apply(&mut cfg, &section, &key, &acc, lineno + 1)?;
            } else {
                pending = Some((key, acc));
            }
            continue;
        }
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(format!("lint-hotpaths.toml:{}: expected `key = [...]`", lineno + 1));
        };
        let key = key.trim().to_string();
        let value = value.trim().to_string();
        if balanced(&value) {
            apply(&mut cfg, &section, &key, &value, lineno + 1)?;
        } else {
            pending = Some((key, value));
        }
    }
    if let Some((key, _)) = pending {
        return Err(format!("lint-hotpaths.toml: unterminated array for key {key:?}"));
    }
    Ok(cfg)
}

/// Strip a `#` comment, honouring quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// True when brackets and quotes close: the value is complete.
fn balanced(value: &str) -> bool {
    let mut in_str = false;
    let mut depth = 0i32;
    for c in value.chars() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            _ => {}
        }
    }
    !in_str && depth == 0
}

fn parse_string_array(value: &str, lineno: usize) -> Result<Vec<String>, String> {
    let inner = value
        .trim()
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| format!("lint-hotpaths.toml:{lineno}: value must be an array of strings"))?;
    let mut out = Vec::new();
    for piece in inner.split(',') {
        let piece = piece.trim();
        if piece.is_empty() {
            continue; // trailing comma / empty array
        }
        let s = piece
            .strip_prefix('"')
            .and_then(|p| p.strip_suffix('"'))
            .ok_or_else(|| {
                format!("lint-hotpaths.toml:{lineno}: array items must be double-quoted")
            })?;
        out.push(s.to_string());
    }
    Ok(out)
}

fn apply(
    cfg: &mut LintConfig,
    section: &str,
    key: &str,
    value: &str,
    lineno: usize,
) -> Result<(), String> {
    let items = parse_string_array(value, lineno)?;
    let target = match (section, key) {
        ("hot-path-no-alloc", "functions") => &mut cfg.hotpaths,
        ("no-random-state", "allow-files") => &mut cfg.r1_allow,
        ("no-wall-clock", "allow-files") => &mut cfg.r2_allow,
        ("no-panic-in-parsers", "files") => &mut cfg.r4_files,
        ("checked-narrowing", "files") => &mut cfg.r5_files,
        _ => {
            return Err(format!(
                "lint-hotpaths.toml:{lineno}: unknown setting `{key}` in section `[{section}]`"
            ))
        }
    };
    target.extend(items);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_arrays_and_comments() {
        let cfg = from_manifest(
            "# manifest\n\
             [hot-path-no-alloc]\n\
             functions = [\n\
                 \"Network::step\", # the main loop\n\
                 \"RouteTable::route_packet\",\n\
             ]\n\
             [no-wall-clock]\n\
             allow-files = [\"util/bench.rs\"]\n\
             [no-random-state]\n\
             allow-files = []\n",
        )
        .unwrap();
        assert!(cfg.hotpaths.contains("Network::step"));
        assert!(cfg.hotpaths.contains("RouteTable::route_packet"));
        assert!(cfg.r2_allow.contains("util/bench.rs"));
        assert!(cfg.r1_allow.is_empty());
    }

    #[test]
    fn unknown_keys_are_rejected() {
        let err = from_manifest("[hot-path-no-alloc]\nfuncs = [\"A::b\"]\n").unwrap_err();
        assert!(err.contains("unknown setting"), "{err}");
    }

    #[test]
    fn unterminated_arrays_are_rejected() {
        let err = from_manifest("[checked-narrowing]\nfiles = [\"a.rs\",\n").unwrap_err();
        assert!(err.contains("unterminated"), "{err}");
    }

    #[test]
    fn hash_inside_quotes_is_not_a_comment() {
        let cfg = from_manifest("[no-panic-in-parsers]\nfiles = [\"a#b.rs\"]\n").unwrap();
        assert!(cfg.r4_files.contains("a#b.rs"));
    }
}
