//! Structural pass over the token stream: `#[cfg(test)]` item skipping and
//! an outline of `impl` blocks / `fn` bodies, so rules can be scoped to
//! qualified function names (`Type::method`) without a full parse.

use crate::lexer::{Kind, Tok};

fn is_punct(t: &Tok, s: &str) -> bool {
    t.kind == Kind::Punct && t.text == s
}

fn is_id(t: &Tok, s: &str) -> bool {
    t.kind == Kind::Id && t.text == s
}

/// `k` indexes a `{`; returns the index of its matching `}` (or the last
/// token of a truncated stream).
pub fn match_brace(toks: &[Tok], k: usize) -> usize {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate().skip(k) {
        if is_punct(t, "{") {
            depth += 1;
        } else if is_punct(t, "}") {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// `k` indexes a `<`; returns the index just past the matching `>`.
/// A `>` preceded by `-` or `=` is an arrow (`->`, `=>`), not a closer.
pub fn skip_angles(toks: &[Tok], k: usize) -> usize {
    let mut depth = 0i32;
    let mut j = k;
    while j < toks.len() {
        let t = &toks[j];
        if is_punct(t, "<") {
            depth += 1;
        } else if is_punct(t, ">") {
            let arrow = j > 0 && {
                let p = &toks[j - 1];
                is_punct(p, "-") || is_punct(p, "=")
            };
            if !arrow {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
        }
        j += 1;
    }
    j
}

/// Boolean mask: tokens inside `#[cfg(test)]` items, including the
/// attribute itself and any stacked attributes, through the item's whole
/// balanced `{…}` block (or to its terminating `;`).
pub fn cfg_test_skips(toks: &[Tok]) -> Vec<bool> {
    let mut skipped = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if !is_cfg_test_attr(toks, i) {
            i += 1;
            continue;
        }
        let start = i;
        let mut j = i + 7;
        // Skip any further stacked attributes.
        while j + 1 < toks.len() && is_punct(&toks[j], "#") && toks[j + 1].text == "[" {
            let mut depth = 0i32;
            j += 1;
            while j < toks.len() {
                if toks[j].text == "[" {
                    depth += 1;
                } else if toks[j].text == "]" {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        // First `{` or `;` at ()/[] nesting 0 ends the item header.
        let mut nest = 0i32;
        let mut end: Option<usize> = None;
        while j < toks.len() {
            let t = &toks[j];
            if t.kind == Kind::Punct {
                match t.text.as_str() {
                    "(" | "[" => nest += 1,
                    ")" | "]" => nest -= 1,
                    "{" if nest == 0 => {
                        end = Some(match_brace(toks, j));
                        break;
                    }
                    ";" if nest == 0 => {
                        end = Some(j);
                        break;
                    }
                    _ => {}
                }
            }
            j += 1;
        }
        let end = end.unwrap_or_else(|| toks.len().saturating_sub(1));
        for s in skipped.iter_mut().take(end + 1).skip(start) {
            *s = true;
        }
        i = end + 1;
    }
    skipped
}

fn is_cfg_test_attr(toks: &[Tok], i: usize) -> bool {
    if i + 7 > toks.len() {
        return false;
    }
    is_punct(&toks[i], "#")
        && is_punct(&toks[i + 1], "[")
        && is_id(&toks[i + 2], "cfg")
        && is_punct(&toks[i + 3], "(")
        && is_id(&toks[i + 4], "test")
        && is_punct(&toks[i + 5], ")")
        && is_punct(&toks[i + 6], "]")
}

/// A function body located in the token stream.
pub struct FnSpan {
    /// `Type::name` inside an impl block, bare `name` at module level.
    pub qual: String,
    /// Inclusive token range of the body, from `{` to `}`.
    pub body_start: usize,
    pub body_end: usize,
}

/// Outline all non-test `fn` bodies with impl-qualified names. The impl
/// type is the last path segment before the block opens (`impl<T> Trait
/// for Type<T>` → `Type`), which is exactly the granularity the hot-path
/// manifest uses.
pub fn outline(toks: &[Tok], skipped: &[bool]) -> Vec<FnSpan> {
    let mut fns: Vec<FnSpan> = Vec::new();
    let mut impl_stack: Vec<(String, i32)> = Vec::new();
    let mut depth = 0i32;
    let mut i = 0usize;
    while i < toks.len() {
        if skipped[i] {
            i += 1;
            continue;
        }
        let t = &toks[i];
        if is_punct(t, "{") {
            depth += 1;
        } else if is_punct(t, "}") {
            depth -= 1;
            while impl_stack.last().is_some_and(|top| top.1 >= depth) {
                impl_stack.pop();
            }
        } else if is_id(t, "impl") {
            let mut j = i + 1;
            if j < toks.len() && is_punct(&toks[j], "<") {
                j = skip_angles(toks, j);
            }
            let mut cur: Vec<String> = Vec::new();
            while j < toks.len() {
                let tj = &toks[j];
                if is_punct(tj, "{") || is_punct(tj, ";") {
                    break;
                }
                if is_id(tj, "for") {
                    cur.clear();
                } else if is_id(tj, "where") {
                    break;
                } else if is_punct(tj, "<") {
                    j = skip_angles(toks, j);
                    continue;
                } else if tj.kind == Kind::Id {
                    cur.push(tj.text.clone());
                }
                j += 1;
            }
            while j < toks.len() && !(is_punct(&toks[j], "{") || is_punct(&toks[j], ";")) {
                j += 1;
            }
            if j < toks.len() && toks[j].text == "{" {
                if let Some(last) = cur.last() {
                    impl_stack.push((last.clone(), depth));
                }
            }
            i = j;
            continue;
        } else if is_id(t, "fn") {
            if i + 1 < toks.len() && toks[i + 1].kind == Kind::Id {
                let name = &toks[i + 1].text;
                let qual = match impl_stack.last() {
                    Some((ty, _)) => format!("{ty}::{name}"),
                    None => name.clone(),
                };
                let mut k = i + 2;
                let mut nest = 0i32;
                while k < toks.len() {
                    let tk = &toks[k];
                    if tk.kind == Kind::Punct {
                        match tk.text.as_str() {
                            "(" | "[" => nest += 1,
                            ")" | "]" => nest -= 1,
                            "{" if nest == 0 => break,
                            ";" if nest == 0 => break,
                            _ => {}
                        }
                    }
                    k += 1;
                }
                if k < toks.len() && toks[k].text == "{" {
                    fns.push(FnSpan {
                        qual,
                        body_start: k,
                        body_end: match_brace(toks, k),
                    });
                }
            }
        }
        i += 1;
    }
    fns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn outline_of(src: &str) -> Vec<String> {
        let lexed = lex(src);
        let skipped = cfg_test_skips(&lexed.toks);
        outline(&lexed.toks, &skipped)
            .into_iter()
            .map(|f| f.qual)
            .collect()
    }

    #[test]
    fn qualifies_impl_methods() {
        let names = outline_of(
            "impl Foo { fn a(&self) {} }\n\
             impl<T: Clone> Bar<T> { fn b() {} }\n\
             impl Iterator for Baz { fn next(&mut self) -> Option<u8> { None } }\n\
             fn free() {}",
        );
        assert_eq!(names, vec!["Foo::a", "Bar::b", "Baz::next", "free"]);
    }

    #[test]
    fn generic_return_arrows_do_not_confuse_angles() {
        let names = outline_of(
            "impl Map { fn get(&self) -> Option<Vec<u8>> { None } fn put(&mut self) {} }",
        );
        assert_eq!(names, vec!["Map::get", "Map::put"]);
    }

    #[test]
    fn cfg_test_modules_are_skipped() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn dead() {}\n}\nfn live2() {}";
        assert_eq!(outline_of(src), vec!["live", "live2"]);
    }

    #[test]
    fn nested_impls_pop_with_braces() {
        let names = outline_of(
            "impl A { fn fa(&self) { } }\nimpl B { fn fb(&self) { let _ = |x: u8| x; } }",
        );
        assert_eq!(names, vec!["A::fa", "B::fb"]);
    }

    #[test]
    fn where_clause_does_not_leak_into_type_name() {
        let names = outline_of("impl<T> Wrap<T> where T: Clone { fn w() {} }");
        assert_eq!(names, vec!["Wrap::w"]);
    }
}
