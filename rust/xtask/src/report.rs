//! Stable machine-readable lint report. Key order, array order (file,
//! line, col, rule), and formatting are all deterministic so CI artifacts
//! diff cleanly between runs.

use crate::baseline::{BaselineEntry, Classified};
use crate::json::write_str;
use crate::lint::Violation;

pub fn render(root: &str, viols: &[Violation], classified: &Classified) -> String {
    let suppressed = classified
        .statuses
        .iter()
        .filter(|s| **s == crate::baseline::Status::Suppressed)
        .count();
    let baselined = classified
        .statuses
        .iter()
        .filter(|s| **s == crate::baseline::Status::Baselined)
        .count();

    let mut out = String::new();
    out.push_str("{\n  \"version\": 1,\n  \"root\": ");
    write_str(&mut out, root);
    out.push_str(&format!(
        ",\n  \"summary\": {{\"total\": {}, \"new\": {}, \"baselined\": {}, \
         \"suppressed\": {}, \"stale_baseline\": {}}},\n  \"violations\": [",
        viols.len(),
        classified.new_count,
        baselined,
        suppressed,
        classified.stale.len(),
    ));
    let mut first = true;
    for (v, status) in viols.iter().zip(&classified.statuses) {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str("\n    {\"rule\": ");
        write_str(&mut out, v.rule);
        out.push_str(", \"file\": ");
        write_str(&mut out, &v.file);
        out.push_str(&format!(", \"line\": {}, \"col\": {}, \"snippet\": ", v.line, v.col));
        write_str(&mut out, &v.snippet);
        out.push_str(", \"status\": ");
        write_str(&mut out, status.as_str());
        out.push('}');
    }
    out.push_str("\n  ],\n  \"stale_baseline\": [");
    let mut first = true;
    for e in &classified.stale {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str("\n    ");
        out.push_str(&render_stale(e));
    }
    out.push_str("\n  ]\n}\n");
    out
}

fn render_stale(e: &BaselineEntry) -> String {
    let mut s = String::new();
    s.push_str("{\"rule\": ");
    write_str(&mut s, &e.rule);
    s.push_str(", \"file\": ");
    write_str(&mut s, &e.file);
    s.push_str(", \"snippet\": ");
    write_str(&mut s, &e.snippet);
    s.push_str(&format!(", \"count\": {}}}", e.count));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::classify;
    use crate::json;

    #[test]
    fn report_is_valid_json_with_stable_keys() {
        let viols = vec![Violation {
            rule: crate::lint::R1_NO_RANDOM_STATE,
            file: "a.rs".to_string(),
            line: 3,
            col: 9,
            snippet: "let m: HashMap<u8, u8> = \"x\\n\".into();".to_string(),
            suppressed: false,
        }];
        let classified = classify(&viols, &[]);
        let text = render("rust/src", &viols, &classified);
        let v = json::parse(&text).expect("report parses");
        assert_eq!(
            v.get("summary")
                .and_then(|s| s.get("new"))
                .and_then(json::Value::as_u64),
            Some(1)
        );
        let arr = v.get("violations").and_then(json::Value::as_arr).unwrap();
        assert_eq!(arr[0].get("line").and_then(json::Value::as_u64), Some(3));
        assert_eq!(
            arr[0].get("status").and_then(json::Value::as_str),
            Some("new")
        );
    }
}
