//! The five invariant rules and the per-file / per-tree lint drivers.
//!
//! | id                    | contract                                          |
//! |-----------------------|---------------------------------------------------|
//! | `no-random-state`     | no `HashMap`/`HashSet` outside the allowlist      |
//! | `no-wall-clock`       | no `Instant`/`SystemTime` outside the allowlist   |
//! | `hot-path-no-alloc`   | manifest-registered fns may not allocate          |
//! | `no-panic-in-parsers` | decode paths: no unwrap/expect/panic!/`x[i]`      |
//! | `checked-narrowing`   | packed-table files: no bare `as u8/u16/u32`       |
//!
//! Suppression: a comment `// allow(resipi::<rule>): reason` on the
//! violation line, directly above it, or anywhere in the contiguous block
//! of comment lines above it. `resipi::all` suppresses every rule.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::lexer::{lex, Kind, Tok};
use crate::outline::{cfg_test_skips, outline};

pub const R1_NO_RANDOM_STATE: &str = "no-random-state";
pub const R2_NO_WALL_CLOCK: &str = "no-wall-clock";
pub const R3_HOT_PATH_NO_ALLOC: &str = "hot-path-no-alloc";
pub const R4_NO_PANIC_IN_PARSERS: &str = "no-panic-in-parsers";
pub const R5_CHECKED_NARROWING: &str = "checked-narrowing";

pub const RULES: [&str; 5] = [
    R1_NO_RANDOM_STATE,
    R2_NO_WALL_CLOCK,
    R3_HOT_PATH_NO_ALLOC,
    R4_NO_PANIC_IN_PARSERS,
    R5_CHECKED_NARROWING,
];

/// One-line rationale shown with each diagnostic.
pub fn rule_help(rule: &str) -> &'static str {
    match rule {
        R1_NO_RANDOM_STATE => {
            "HashMap/HashSet iteration order is nondeterministic; use BTreeMap/BTreeSet \
             or a sorted Vec"
        }
        R2_NO_WALL_CLOCK => {
            "wall-clock time must not reach simulation state; timing belongs in \
             util/bench.rs or experiments/perf.rs"
        }
        R3_HOT_PATH_NO_ALLOC => {
            "this function is registered in lint-hotpaths.toml and must not allocate; \
             use a pre-sized scratch buffer"
        }
        R4_NO_PANIC_IN_PARSERS => {
            "parser/decode paths must return Err, never panic: no unwrap/expect/panic! \
             or bare slice indexing"
        }
        R5_CHECKED_NARROWING => {
            "bare narrowing casts can silently alias packed indices; use try_from with \
             a construction-time error"
        }
        _ => "unknown rule",
    }
}

/// Methods whose receiver-side call allocates (or may allocate).
const DENY_METHODS: [&str; 19] = [
    "push",
    "push_back",
    "push_front",
    "insert",
    "collect",
    "to_vec",
    "to_owned",
    "to_string",
    "clone",
    "extend",
    "extend_from_slice",
    "append",
    "reserve",
    "reserve_exact",
    "resize",
    "split_off",
    "join",
    "repeat",
    "concat",
];

/// Allocating associated-function paths (`Type::func`).
const PATH_DENY: [(&str, &str); 5] = [
    ("Box", "new"),
    ("String", "from"),
    ("Vec", "with_capacity"),
    ("String", "with_capacity"),
    ("Vec", "from"),
];

const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

/// Keywords that may legitimately precede `[` without forming an index
/// expression (`let [a, b] = …`, `if let [x] = …`, `for [k, v] in …`).
const KEYWORDS: [&str; 43] = [
    "let",
    "in",
    "as",
    "mut",
    "ref",
    "move",
    "return",
    "if",
    "else",
    "match",
    "const",
    "static",
    "break",
    "continue",
    "where",
    "for",
    "while",
    "loop",
    "impl",
    "fn",
    "pub",
    "use",
    "mod",
    "struct",
    "enum",
    "trait",
    "type",
    "dyn",
    "unsafe",
    "crate",
    "super",
    "self",
    "Self",
    "box",
    "yield",
    "async",
    "await",
    "become",
    "do",
    "macro",
    "union",
    "true",
    "false",
];

/// Rule scoping, loaded from `lint-hotpaths.toml` (see
/// [`crate::manifest`]). File paths are relative to the linted root with
/// `/` separators.
#[derive(Debug, Default, Clone)]
pub struct LintConfig {
    /// `Type::method` / free-fn names whose bodies must not allocate (R3).
    pub hotpaths: BTreeSet<String>,
    /// Files where HashMap/HashSet are tolerated (R1).
    pub r1_allow: BTreeSet<String>,
    /// Files where Instant/SystemTime are tolerated (R2).
    pub r2_allow: BTreeSet<String>,
    /// Parser/decode files held to panic-freedom (R4).
    pub r4_files: BTreeSet<String>,
    /// Packed-encoding files held to checked narrowing (R5).
    pub r5_files: BTreeSet<String>,
}

#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub snippet: String,
    pub suppressed: bool,
}

fn has_allow_marker(text: &str, rule: &str) -> bool {
    let mut rest = text;
    while let Some(at) = rest.find("allow(resipi::") {
        let after = &rest[at..];
        let Some(end) = after.find(')') else {
            return false;
        };
        let inner = &after["allow(".len()..end];
        for part in inner.split(',') {
            let slug = part.trim().replace("resipi::", "").replace('_', "-");
            if slug == rule || slug == "all" {
                return true;
            }
        }
        rest = &after[end + 1..];
    }
    false
}

/// A marker suppresses on its own line, on the line directly below it, or
/// from anywhere inside the contiguous block of comment-only lines above
/// the violation (multi-line justifications are encouraged).
fn suppressed(comments: &BTreeMap<u32, String>, lines: &[&str], rule: &str, line: u32) -> bool {
    if comments
        .get(&line)
        .is_some_and(|t| has_allow_marker(t, rule))
    {
        return true;
    }
    let mut l = line.saturating_sub(1);
    while l >= 1 {
        let Some(text) = comments.get(&l) else {
            break;
        };
        if has_allow_marker(text, rule) {
            return true;
        }
        let src = lines.get(l as usize - 1).map_or("", |s| s.trim());
        if !(src.starts_with("//") || src.starts_with("/*") || src.starts_with('*')) {
            break;
        }
        l -= 1;
    }
    false
}

/// Lint one file's source text. `rel` is the root-relative path used for
/// scoping and reporting.
pub fn lint_file(text: &str, rel: &str, cfg: &LintConfig) -> Vec<Violation> {
    let lines: Vec<&str> = text.split('\n').collect();
    let lexed = lex(text);
    let toks = &lexed.toks;
    let comments = &lexed.comments;
    let skipped = cfg_test_skips(toks);
    let fns = outline(toks, &skipped);
    let mut viols: Vec<Violation> = Vec::new();

    let empty = Tok {
        kind: Kind::Punct,
        text: String::new(),
        line: 0,
        col: 0,
    };
    fn tok_at<'a>(toks: &'a [Tok], empty: &'a Tok, idx: usize) -> &'a Tok {
        toks.get(idx).unwrap_or(empty)
    }

    let mut emit = |rule: &'static str, t: &Tok| {
        let snippet = lines
            .get(t.line as usize - 1)
            .map_or_else(String::new, |s| s.trim().to_string());
        viols.push(Violation {
            rule,
            file: rel.to_string(),
            line: t.line,
            col: t.col,
            snippet,
            suppressed: suppressed(comments, &lines, rule, t.line),
        });
    };

    let r4 = cfg.r4_files.contains(rel);
    let r5 = cfg.r5_files.contains(rel);
    for idx in 0..toks.len() {
        if skipped[idx] {
            continue;
        }
        let t = &toks[idx];
        let nxt = tok_at(toks, &empty, idx + 1);
        let nx2 = tok_at(toks, &empty, idx + 2);
        if t.kind == Kind::Id
            && (t.text == "HashMap" || t.text == "HashSet")
            && !cfg.r1_allow.contains(rel)
        {
            emit(R1_NO_RANDOM_STATE, t);
        }
        if t.kind == Kind::Id
            && (t.text == "Instant" || t.text == "SystemTime")
            && !cfg.r2_allow.contains(rel)
        {
            emit(R2_NO_WALL_CLOCK, t);
        }
        if r5
            && t.kind == Kind::Id
            && t.text == "as"
            && nxt.kind == Kind::Id
            && matches!(nxt.text.as_str(), "u8" | "u16" | "u32")
        {
            emit(R5_CHECKED_NARROWING, t);
        }
        if r4 {
            if t.kind == Kind::Punct
                && t.text == "."
                && nxt.kind == Kind::Id
                && (nxt.text == "unwrap" || nxt.text == "expect")
                && nx2.text == "("
            {
                emit(R4_NO_PANIC_IN_PARSERS, nxt);
            }
            if t.kind == Kind::Id
                && PANIC_MACROS.contains(&t.text.as_str())
                && nxt.kind == Kind::Punct
                && nxt.text == "!"
            {
                emit(R4_NO_PANIC_IN_PARSERS, t);
            }
            if t.kind == Kind::Punct && t.text == "[" && idx > 0 {
                // `x[i]` / `f()[i]` / `x?[i]` index and can panic;
                // `let [a, b] = …` and `#[attr]` / `vec![…]` do not.
                let prev = &toks[idx - 1];
                let postfix = (prev.kind == Kind::Punct
                    && matches!(prev.text.as_str(), ")" | "]" | "?"))
                    || (prev.kind == Kind::Id && !KEYWORDS.contains(&prev.text.as_str()));
                if postfix {
                    emit(R4_NO_PANIC_IN_PARSERS, t);
                }
            }
        }
    }

    for f in &fns {
        if !cfg.hotpaths.contains(&f.qual) {
            continue;
        }
        for idx in f.body_start..=f.body_end {
            if idx >= toks.len() || skipped[idx] {
                continue;
            }
            let t = &toks[idx];
            let nxt = tok_at(toks, &empty, idx + 1);
            let nx2 = tok_at(toks, &empty, idx + 2);
            let nx3 = tok_at(toks, &empty, idx + 3);
            if t.kind == Kind::Punct
                && t.text == "."
                && nxt.kind == Kind::Id
                && DENY_METHODS.contains(&nxt.text.as_str())
                && nx2.text == "("
            {
                emit(R3_HOT_PATH_NO_ALLOC, nxt);
            }
            if t.kind == Kind::Id
                && (t.text == "format" || t.text == "vec")
                && nxt.kind == Kind::Punct
                && nxt.text == "!"
            {
                emit(R3_HOT_PATH_NO_ALLOC, t);
            }
            if t.kind == Kind::Id
                && nxt.text == "::"
                && nx2.kind == Kind::Id
                && PATH_DENY
                    .iter()
                    .any(|&(a, b)| a == t.text && b == nx2.text)
                && nx3.text == "("
            {
                emit(R3_HOT_PATH_NO_ALLOC, t);
            }
        }
    }

    viols
}

/// Lint every `.rs` file under `root` (recursively), sorted by
/// (file, line, col, rule) for a stable report.
pub fn lint_tree(root: &Path, cfg: &LintConfig) -> io::Result<Vec<Violation>> {
    let mut files: Vec<(String, PathBuf)> = Vec::new();
    collect_rs(root, root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for (rel, full) in &files {
        let text = fs::read_to_string(full)?;
        out.extend(lint_file(&text, rel, cfg));
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.col, a.rule).cmp(&(b.file.as_str(), b.line, b.col, b.rule))
    });
    Ok(out)
}

fn collect_rs(base: &Path, dir: &Path, out: &mut Vec<(String, PathBuf)>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(base, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(base)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push((rel, path));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_all(rel: &str) -> LintConfig {
        let mut c = LintConfig::default();
        c.r4_files.insert(rel.to_string());
        c.r5_files.insert(rel.to_string());
        c
    }

    #[test]
    fn array_literals_and_attributes_are_not_indexing() {
        let src = "pub fn f() -> [u8; 2] {\n\
                   \x20   let [a, b] = [1u8, 2u8];\n\
                   \x20   [a, b]\n\
                   }\n\
                   #[derive(Debug)]\n\
                   pub struct S;\n";
        let v = lint_file(src, "p.rs", &cfg_all("p.rs"));
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn postfix_indexing_is_flagged() {
        let src = "pub fn f(x: &[u8]) -> u8 { x[0] }\n";
        let v = lint_file(src, "p.rs", &cfg_all("p.rs"));
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, R4_NO_PANIC_IN_PARSERS);
    }

    #[test]
    fn strings_do_not_trigger_rules() {
        let src = "pub fn f() -> &'static str { \"HashMap Instant .unwrap() x[0]\" }\n";
        let mut c = cfg_all("p.rs");
        c.hotpaths.insert("f".to_string());
        let v = lint_file(src, "p.rs", &c);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn cfg_test_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n}\n";
        let v = lint_file(src, "p.rs", &LintConfig::default());
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn suppression_scans_comment_blocks() {
        let src = "fn f() {\n\
                   \x20   // allow(resipi::no-random-state): fixture reason\n\
                   \x20   // spanning two lines.\n\
                   \x20   let m = std::collections::HashMap::<u8, u8>::new();\n\
                   \x20   drop(m);\n\
                   }\n";
        let v = lint_file(src, "p.rs", &LintConfig::default());
        assert_eq!(v.len(), 1);
        assert!(v[0].suppressed);
    }

    #[test]
    fn rule_scoping_is_per_file() {
        let src = "pub fn f(x: usize) -> u16 { x as u16 }\n";
        assert_eq!(lint_file(src, "in.rs", &cfg_all("in.rs")).len(), 1);
        assert!(lint_file(src, "out.rs", &cfg_all("in.rs")).is_empty());
    }
}
