//! Tiny JSON reader/writer (the image ships no serde). Panic-free: every
//! parse error surfaces as `Err(String)` with a byte offset. Only the
//! constructs the baseline and report files use are supported — which is
//! all of JSON except exotic number forms (handled as f64 text).

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
}

pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("JSON: trailing bytes at offset {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self
            .b
            .get(self.i)
            .is_some_and(|c| matches!(c, b' ' | b'\t' | b'\r' | b'\n'))
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "JSON: expected {:?} at offset {}",
                c as char, self.i
            ))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("JSON: bad literal at offset {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Value::Null),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("JSON: unexpected byte at offset {}", self.i)),
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("JSON: bad number at offset {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = Vec::new();
        loop {
            match self.peek() {
                None => return Err("JSON: unterminated string".to_string()),
                Some(b'"') => {
                    self.i += 1;
                    return String::from_utf8(out)
                        .map_err(|_| "JSON: string is not valid UTF-8".to_string());
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("JSON: dangling escape")?;
                    self.i += 1;
                    match esc {
                        b'"' | b'\\' | b'/' => out.push(esc),
                        b'n' => out.push(b'\n'),
                        b't' => out.push(b'\t'),
                        b'r' => out.push(b'\r'),
                        b'b' => out.push(0x08),
                        b'f' => out.push(0x0c),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .and_then(|s| std::str::from_utf8(s).ok())
                                .and_then(|s| u32::from_str_radix(s, 16).ok())
                                .ok_or_else(|| {
                                    format!("JSON: bad \\u escape at offset {}", self.i)
                                })?;
                            self.i += 4;
                            // Surrogate pairs are not needed by our reports;
                            // map lone surrogates to U+FFFD.
                            let ch = char::from_u32(hex).unwrap_or('\u{fffd}');
                            let mut buf = [0u8; 4];
                            out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                        }
                        _ => return Err(format!("JSON: bad escape at offset {}", self.i)),
                    }
                }
                Some(c) => {
                    out.push(c);
                    self.i += 1;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("JSON: expected , or ] at offset {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(format!("JSON: expected , or }} at offset {}", self.i)),
            }
        }
    }
}

/// Append `s` to `out` as a JSON string literal (with quotes).
pub fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_baseline_shape() {
        let v = parse(
            r#"{"version": 1, "entries": [{"rule": "no-random-state", "count": 2}]}"#,
        )
        .unwrap();
        assert_eq!(v.get("version").and_then(Value::as_u64), Some(1));
        let e = v.get("entries").and_then(Value::as_arr).unwrap();
        assert_eq!(
            e[0].get("rule").and_then(Value::as_str),
            Some("no-random-state")
        );
        assert_eq!(e[0].get("count").and_then(Value::as_u64), Some(2));
    }

    #[test]
    fn escapes_round_trip() {
        let mut s = String::new();
        write_str(&mut s, "a\"b\\c\nd");
        assert_eq!(s, r#""a\"b\\c\nd""#);
        let back = parse(&s).unwrap();
        assert_eq!(back.as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
    }
}
