//! The repository's own `rust/src` must lint clean against the committed
//! baseline: running the tier-1 suite therefore enforces the invariants
//! even where CI's dedicated `lint` job is skipped.

use std::fs;
use std::path::Path;

use xtask::baseline::{classify, parse_baseline};
use xtask::lint::lint_tree;
use xtask::manifest::from_manifest;

#[test]
fn repo_sources_have_no_new_violations() {
    let repo = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let manifest_text =
        fs::read_to_string(repo.join("rust/lint-hotpaths.toml")).expect("manifest readable");
    let cfg = from_manifest(&manifest_text).expect("manifest parses");
    assert!(
        cfg.hotpaths.contains("Network::step"),
        "manifest lost the core hot path"
    );

    let viols = lint_tree(&repo.join("rust/src"), &cfg).expect("tree lints");
    let baseline_text =
        fs::read_to_string(repo.join("lint-baseline.json")).expect("baseline readable");
    let baseline = parse_baseline(&baseline_text).expect("baseline parses");
    let classified = classify(&viols, &baseline);

    let fresh: Vec<String> = viols
        .iter()
        .zip(&classified.statuses)
        .filter(|(_, s)| **s == xtask::baseline::Status::New)
        .map(|(v, _)| format!("{}:{}:{} [{}] {}", v.file, v.line, v.col, v.rule, v.snippet))
        .collect();
    assert!(
        fresh.is_empty(),
        "new lint violations (fix, suppress with justification, or bless):\n{}",
        fresh.join("\n")
    );

    // Every suppression in the tree must carry a justification after the
    // rule slug — a bare marker is not an argument.
    for v in viols.iter().filter(|v| v.suppressed) {
        assert!(
            !v.snippet.is_empty(),
            "suppressed violation lost its snippet: {v:?}"
        );
    }
}
