pub struct Hot {
    buf: Vec<u8>,
}

impl Hot {
    pub fn step(&mut self, x: u8) {
        self.buf.push(x);
        let _label = format!("x={x}");
    }

    pub fn cold(&mut self, x: u8) {
        self.buf.push(x);
    }
}
