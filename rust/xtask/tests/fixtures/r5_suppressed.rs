pub fn pack(idx: usize) -> u16 {
    // allow(resipi::checked-narrowing): fixture; idx is a row id already
    // proven < 1024 by the table builder.
    idx as u16
}
