pub fn pack(idx: usize) -> u16 {
    idx as u16
}
