pub fn decode(buf: &[u8]) -> u8 {
    let hi = buf[0];
    let lo = buf.first().copied().unwrap();
    hi.wrapping_add(lo)
}
