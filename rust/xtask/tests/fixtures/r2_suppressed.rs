// allow(resipi::no-wall-clock): fixture; this helper feeds a progress bar
// only and never reaches simulation state.
use std::time::Instant;

// allow(resipi::no-wall-clock): fixture; the return type names the clock.
pub fn stamp() -> Instant {
    // allow(resipi::no_wall_clock): underscore spelling also accepted.
    Instant::now()
}
