pub fn decode(buf: &[u8]) -> u8 {
    // allow(resipi::no-panic-in-parsers): fixture; the caller checked
    // `buf.len() >= 2` at the validated-open boundary.
    let hi = buf[0];
    let lo = buf.first().copied().unwrap(); // allow(resipi::no-panic-in-parsers): fixture
    hi.wrapping_add(lo)
}
