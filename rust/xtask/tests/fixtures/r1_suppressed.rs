// allow(resipi::no-random-state): fixture demonstrating suppression; the
// map is drained into a sorted Vec before any iteration order can leak.
use std::collections::HashMap;

pub fn tally(xs: &[u8]) -> usize {
    // allow(resipi::no-random-state): same justification as the import.
    let mut seen = HashMap::new();
    for &x in xs {
        seen.insert(x, ());
    }
    seen.len()
}
