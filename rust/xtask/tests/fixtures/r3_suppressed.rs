pub struct Hot {
    buf: Vec<u8>,
}

impl Hot {
    pub fn step(&mut self, x: u8) {
        // allow(resipi::hot-path-no-alloc): fixture; capacity is reserved
        // once at construction, so this push never reallocates.
        self.buf.push(x);
    }
}
