//! Each rule demonstrated on a known-bad fixture plus a suppressed
//! variant, asserting exact rule IDs, line/col spans, and statuses. The
//! expected spans were cross-checked against the bootstrap mirror
//! (`tools/gen_baseline.py`) — if these fail after touching the lexer or
//! outline, the two implementations have diverged.

use std::fs;
use std::path::Path;

use xtask::baseline::classify;
use xtask::lint::{lint_file, LintConfig, Violation};
use xtask::{json, report};

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

fn cfg() -> LintConfig {
    let mut c = LintConfig::default();
    c.hotpaths.insert("Hot::step".to_string());
    for f in ["r4_bad.rs", "r4_suppressed.rs"] {
        c.r4_files.insert(f.to_string());
    }
    for f in ["r5_bad.rs", "r5_suppressed.rs"] {
        c.r5_files.insert(f.to_string());
    }
    c
}

fn spans(name: &str) -> Vec<(String, u32, u32, bool)> {
    lint_file(&fixture(name), name, &cfg())
        .into_iter()
        .map(|v| (v.rule.to_string(), v.line, v.col, v.suppressed))
        .collect()
}

fn s(rule: &str, line: u32, col: u32, suppressed: bool) -> (String, u32, u32, bool) {
    (rule.to_string(), line, col, suppressed)
}

#[test]
fn r1_no_random_state() {
    assert_eq!(
        spans("r1_bad.rs"),
        vec![
            s("no-random-state", 1, 23, false),
            s("no-random-state", 4, 20, false),
        ]
    );
    assert_eq!(
        spans("r1_suppressed.rs"),
        vec![
            s("no-random-state", 3, 23, true),
            s("no-random-state", 7, 20, true),
        ]
    );
}

#[test]
fn r2_no_wall_clock() {
    assert_eq!(
        spans("r2_bad.rs"),
        vec![
            s("no-wall-clock", 1, 16, false),
            s("no-wall-clock", 3, 19, false),
            s("no-wall-clock", 4, 5, false),
        ]
    );
    assert_eq!(
        spans("r2_suppressed.rs"),
        vec![
            s("no-wall-clock", 3, 16, true),
            s("no-wall-clock", 6, 19, true),
            s("no-wall-clock", 8, 5, true),
        ]
    );
}

#[test]
fn r3_hot_path_no_alloc() {
    // Only `Hot::step` is registered: the identical push in `Hot::cold`
    // must NOT be flagged.
    assert_eq!(
        spans("r3_bad.rs"),
        vec![
            s("hot-path-no-alloc", 7, 18, false),
            s("hot-path-no-alloc", 8, 22, false),
        ]
    );
    assert_eq!(
        spans("r3_suppressed.rs"),
        vec![s("hot-path-no-alloc", 9, 18, true)]
    );
}

#[test]
fn r4_no_panic_in_parsers() {
    assert_eq!(
        spans("r4_bad.rs"),
        vec![
            s("no-panic-in-parsers", 2, 17, false),
            s("no-panic-in-parsers", 3, 35, false),
        ]
    );
    // Same-line and block-above markers both work.
    assert_eq!(
        spans("r4_suppressed.rs"),
        vec![
            s("no-panic-in-parsers", 4, 17, true),
            s("no-panic-in-parsers", 5, 35, true),
        ]
    );
    // R4 is scoped: the same source under a non-parser filename is clean.
    assert!(lint_file(&fixture("r4_bad.rs"), "elsewhere.rs", &cfg()).is_empty());
}

#[test]
fn r5_checked_narrowing() {
    assert_eq!(
        spans("r5_bad.rs"),
        vec![s("checked-narrowing", 2, 9, false)]
    );
    assert_eq!(
        spans("r5_suppressed.rs"),
        vec![s("checked-narrowing", 4, 9, true)]
    );
    assert!(lint_file(&fixture("r5_bad.rs"), "elsewhere.rs", &cfg()).is_empty());
}

#[test]
fn json_report_carries_rule_ids_and_spans() {
    let mut viols: Vec<Violation> = Vec::new();
    for name in [
        "r1_bad.rs",
        "r2_bad.rs",
        "r3_bad.rs",
        "r4_bad.rs",
        "r5_bad.rs",
        "r1_suppressed.rs",
    ] {
        viols.extend(lint_file(&fixture(name), name, &cfg()));
    }
    let classified = classify(&viols, &[]);
    let text = report::render("tests/fixtures", &viols, &classified);
    let parsed = json::parse(&text).expect("report is valid JSON");

    let summary = parsed.get("summary").expect("summary");
    assert_eq!(summary.get("new").and_then(json::Value::as_u64), Some(10));
    assert_eq!(
        summary.get("suppressed").and_then(json::Value::as_u64),
        Some(2)
    );

    let arr = parsed
        .get("violations")
        .and_then(json::Value::as_arr)
        .expect("violations array");
    assert_eq!(arr.len(), viols.len());
    let find = |rule: &str, file: &str| {
        arr.iter()
            .find(|v| {
                v.get("rule").and_then(json::Value::as_str) == Some(rule)
                    && v.get("file").and_then(json::Value::as_str) == Some(file)
            })
            .unwrap_or_else(|| panic!("no {rule} in {file}"))
    };
    let r5 = find("checked-narrowing", "r5_bad.rs");
    assert_eq!(r5.get("line").and_then(json::Value::as_u64), Some(2));
    assert_eq!(r5.get("col").and_then(json::Value::as_u64), Some(9));
    assert_eq!(
        r5.get("snippet").and_then(json::Value::as_str),
        Some("idx as u16")
    );
    assert_eq!(r5.get("status").and_then(json::Value::as_str), Some("new"));
    let sup = find("no-random-state", "r1_suppressed.rs");
    assert_eq!(
        sup.get("status").and_then(json::Value::as_str),
        Some("suppressed")
    );
}
